"""Hypothesis property tests for the coalition-formation engine.

Invariants pinned here on random games:

* the jitted partition dynamics (``solve_partition``) reproduce the eager
  Python oracle (``partition_equilibrium_reference``) on small fleets —
  same assignment, matching participation profiles;
* the grand-coalition configuration (M = 1) reduces **bitwise** to the
  existing heterogeneous-NE engine;
* every converged returned partition is certified: no node gains more
  than the tolerance budget by an in-coalition deviation or a coalition
  switch (``verify_partition_batched``);
* singleton partitions (cap = 1) are frozen by construction and their
  solo equilibria are monotone — weakly decreasing in cost, weakly
  increasing in the AoI weight γ (so participation collapses as γ → 0
  only through the duration/cost trade-off).

Heavier fleets run under the ``slow`` marker (nightly split).
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't die, without it
from hypothesis import given, settings, strategies as st  # noqa: E402

import repro.core as C
from repro.core.asymmetric_batched import solve_heterogeneous
from repro.core.coalition import (partition_equilibrium_reference,
                                  solve_partition, verify_partition_batched)

seeds = st.integers(0, 2 ** 31 - 1)


def _dur(n):
    return C.theoretical_duration(n_nodes=n, d_inf=30.0, slope=6.0)


def _fleet(rng, n, b=None):
    """Random game with jittered costs (ties would stress argmax order)."""
    shape = (n,) if b is None else (b, n)
    costs = jnp.asarray(rng.uniform(0.5, 8.0, shape)
                        + rng.uniform(1e-3, 1e-2, shape))
    gammas = jnp.asarray(rng.uniform(0.2, 1.0, shape))
    return costs, gammas


@settings(max_examples=4, deadline=None)
@given(st.integers(3, 4), seeds)
def test_engine_matches_python_oracle(n, seed):
    """Tier-1 smoke diff on tiny fleets — the eager oracle costs tens of
    seconds per game, so bigger fleets live in the ``slow`` variant."""
    m = 2
    rng = np.random.default_rng(seed)
    dur = _dur(n)
    costs, gammas = _fleet(rng, n)
    sol = solve_partition(costs, gammas, dur, n_coalitions=m)
    assign_ref, p_ref, conv_ref, switches_ref = (
        partition_equilibrium_reference(costs, gammas, dur, n_coalitions=m))
    assert bool(sol.converged[0]) == conv_ref
    if not conv_ref:
        return
    np.testing.assert_array_equal(np.asarray(sol.assign[0]),
                                  np.asarray(assign_ref))
    assert int(sol.switches[0]) == switches_ref
    np.testing.assert_allclose(np.asarray(sol.p[0]), np.asarray(p_ref),
                               atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(st.integers(2, 8), seeds)
def test_grand_coalition_reduces_bitwise(n, seed):
    """M = 1 runs the same masked Gauss-Seidel program with an all-true
    mask, whose p·member pin is exact — bitwise equal to the asymmetric
    engine, not merely close."""
    rng = np.random.default_rng(seed)
    dur = _dur(n)
    costs, gammas = _fleet(rng, n, b=4)
    sol = solve_partition(costs, gammas, dur, n_coalitions=1)
    het = solve_heterogeneous(costs, gammas, dur)
    np.testing.assert_array_equal(np.asarray(sol.p), np.asarray(het.p))
    np.testing.assert_array_equal(np.asarray(sol.converged),
                                  np.asarray(het.converged))


@settings(max_examples=6, deadline=None)
@given(st.integers(2, 3), seeds)
def test_returned_partitions_are_certified(m, seed):
    n, b = 6, 6
    rng = np.random.default_rng(seed)
    dur = _dur(n)
    costs, gammas = _fleet(rng, n, b=b)
    sol = solve_partition(costs, gammas, dur, n_coalitions=m, tol=1e-10)
    conv = np.asarray(sol.converged & sol.inner_converged)
    assert conv.any()  # γ > 0 keeps best responses continuous: these settle
    dev = verify_partition_batched(costs, gammas, dur, sol.assign, sol.p,
                                   n_coalitions=m, tol=1e-10)
    assert np.all(np.asarray(dev)[conv] <= 1e-6), np.asarray(dev)


@settings(max_examples=8, deadline=None)
@given(st.floats(0.01, 0.2), st.floats(0.5, 1.0), seeds)
def test_singleton_partition_monotone_as_gamma_shrinks(g_lo, g_hi, seed):
    """cap = 1 singletons decouple the fleet into solo games. Each solo
    best response has increasing differences in (p, γ) — the AoI penalty
    is decreasing in p — so the equilibrium is weakly increasing in γ;
    and with equal γ it is weakly decreasing in cost."""
    n = 6
    rng = np.random.default_rng(seed)
    dur = _dur(n)
    costs = jnp.asarray(np.sort(rng.uniform(0.5, 8.0, n)))
    singles = jnp.arange(n, dtype=jnp.int32)

    def solo(gamma):
        sol = solve_partition(costs, jnp.full((n,), gamma), dur,
                              n_coalitions=n, cap=1, assign0=singles,
                              tol=1e-9)
        assert bool(sol.converged[0]) and int(sol.switches[0]) == 0
        return np.asarray(sol.p[0])

    p_lo, p_hi = solo(g_lo), solo(min(g_hi, g_lo + 1.0))
    if g_hi > g_lo:
        assert np.all(p_hi >= p_lo - 1e-6), (p_lo, p_hi)
    assert np.all(np.diff(p_lo) <= 1e-6), p_lo  # decreasing in cost


@pytest.mark.slow
@settings(max_examples=6, deadline=None)
@given(st.integers(4, 6), st.integers(2, 3), st.integers(1, 3), seeds)
def test_engine_matches_oracle_with_caps_slow(n, m, cap_slack, seed):
    """Nightly: bigger fleets, capped slots, full oracle diff. The oracle
    runs at the default tolerance (it is eager Python — a tight tol costs
    minutes per game); certification re-solves at tol=1e-10, where the
    corner residual ``tol/damping`` amplified by the boundary utility
    slope stays well under the 1e-6 budget."""
    rng = np.random.default_rng(seed)
    dur = _dur(n)
    costs, gammas = _fleet(rng, n)
    cap = min(n, -(-n // m) + cap_slack)  # ceil(n/m) + slack: feasible
    sol = solve_partition(costs, gammas, dur, n_coalitions=m, cap=cap)
    assign_ref, p_ref, conv_ref, _ = partition_equilibrium_reference(
        costs, gammas, dur, n_coalitions=m, cap=cap)
    assert bool(sol.converged[0]) == conv_ref
    if not conv_ref:
        return
    np.testing.assert_array_equal(np.asarray(sol.assign[0]),
                                  np.asarray(assign_ref))
    np.testing.assert_allclose(np.asarray(sol.p[0]), np.asarray(p_ref),
                               atol=1e-5)
    sizes = np.asarray(sol.sizes[0])
    assert sizes.sum() == n and np.all(sizes <= cap)
    tight = solve_partition(costs, gammas, dur, n_coalitions=m, cap=cap,
                            tol=1e-10)
    dev = verify_partition_batched(costs, gammas, dur, tight.assign, tight.p,
                                   n_coalitions=m, cap=cap, tol=1e-10)
    assert float(dev[0]) <= 1e-6
