"""Bucketing correctness: padded-and-sliced == direct, caches prove it.

The serving layer's load-bearing claims, pinned per request family:

* **Bitwise parity** — a request served through a padded bucket (edge-
  replica lanes, sliced back) returns results bitwise-equal to calling
  the direct engine (`solve_heterogeneous` + certification,
  `solve_batched`, `run_campaigns`) on the unpadded inputs. The service
  AOT-compiles the *same* jitted callables the direct paths run, so this
  holds exactly, not to tolerance.
* **Deterministic bucket selection** — same request fields + row count →
  same bucket, and the ladder/chunk policy is a pure function.
* **Compiled-program cache** — the second same-bucket request compiles
  nothing: program count and per-bucket compile stats are flat while the
  hit counter moves.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve import (SCHEMA, Bucket, SweepService, batch_rung,
                         bucket_for, chunk_rows, group_key, parse_request)

# ---------------------------------------------------------------------------
# pure bucketing policy
# ---------------------------------------------------------------------------


def test_batch_rung_ladder():
    assert [batch_rung(r) for r in (1, 2, 3, 5, 8, 9, 33, 64, 500)] == \
        [1, 2, 4, 8, 8, 16, 64, 64, 64]
    assert batch_rung(7, max_batch=4) == 4
    with pytest.raises(ValueError):
        batch_rung(0)


def test_chunk_rows_covers_exactly():
    assert chunk_rows(150, max_batch=64) == [64, 64, 22]
    assert chunk_rows(64, max_batch=64) == [64]
    assert chunk_rows(1, max_batch=64) == [1]
    for rows in (1, 7, 64, 129):
        assert sum(chunk_rows(rows, max_batch=32)) == rows


def test_bucket_selection_deterministic():
    req = parse_request({"schema": SCHEMA, "kind": "ne_solve",
                         "costs": [0.1, 0.2, 0.3], "gammas": 1.0})
    b1 = bucket_for(req, 3)
    b2 = bucket_for(parse_request(req.to_dict()), 3)
    assert b1 == b2 and hash(b1) == hash(b2)
    assert b1.family == "ne" and b1.n == 3 and b1.batch == 4
    assert b1.label == "ne/n3/b4"
    # row count maps through the ladder; N is never padded
    assert bucket_for(req, 5).batch == 8
    assert bucket_for(req, 5).n == 3


def test_bucket_statics_split_programs():
    """Different statics (solver knobs) are different buckets."""
    base = {"schema": SCHEMA, "kind": "ne_solve", "costs": [0.1, 0.2]}
    r1 = parse_request(base)
    r2 = parse_request({**base, "max_iters": 99})
    assert bucket_for(r1, 1) != bucket_for(r2, 1)
    assert bucket_for(r1, 1) == bucket_for(parse_request(dict(base)), 1)


def test_group_key_separates_duration_models():
    """Calibrate rows share one d_tab per dispatch: dur is in the key."""
    a = parse_request({"schema": SCHEMA, "kind": "calibrate", "n_nodes": 4,
                       "cost": 0.1})
    b = parse_request({"schema": SCHEMA, "kind": "calibrate", "n_nodes": 4,
                       "cost": 0.2, "dur": {"d_inf": 20.0}})
    c = parse_request({"schema": SCHEMA, "kind": "calibrate", "n_nodes": 4,
                       "cost": 0.3})
    assert group_key(a) != group_key(b)
    assert group_key(a) == group_key(c)  # cost is row data, not shared


def test_bucket_mesh_rounding():
    req = parse_request({"schema": SCHEMA, "kind": "ne_solve",
                         "costs": [0.1, 0.2]})
    assert bucket_for(req, 3, mesh_axes=(8,)).batch == 8
    assert bucket_for(req, 9, mesh_axes=(8,)).batch == 16
    assert isinstance(bucket_for(req, 3), Bucket)


# ---------------------------------------------------------------------------
# bitwise parity per request family
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def task():
    from repro.federated.tasks import synthetic_mlp_task
    return synthetic_mlp_task(image_shape=(4, 4, 1), hidden=4, val_size=32)


@pytest.fixture(scope="module")
def svc(task):
    from repro.optim import sgd
    service = SweepService(max_batch=8, task=task, opt=sgd(0.15))
    yield service
    service.close()


def test_ne_padded_bitwise_equals_direct(svc):
    """3 rows pad to a b4 bucket; every lane matches the direct solve."""
    from repro.core.asymmetric_batched import (solve_heterogeneous,
                                               verify_equilibrium_batched)
    from repro.core.duration import theoretical_duration

    costs = [[0.05, 0.1, 0.2], [0.3, 0.02, 0.15], [0.12, 0.12, 0.12]]
    gammas = [[1.5, 1.0, 2.0], [0.5, 0.5, 0.5], [2.0, 1.0, 0.1]]
    resps = svc.serve([
        {"schema": SCHEMA, "kind": "ne_solve", "costs": c, "gammas": g}
        for c, g in zip(costs, gammas)])
    assert [r.ok for r in resps] == [True] * 3
    assert {r.bucket for r in resps} == {"ne/n3/b4"}  # padded 3 -> 4

    dur = theoretical_duration(3, d_inf=35.0, slope=8.0, horizon=500.0)
    sol = solve_heterogeneous(jnp.asarray(costs), jnp.asarray(gammas), dur)
    dev = verify_equilibrium_batched(jnp.asarray(costs),
                                     jnp.asarray(gammas), dur, sol.p)
    for i, r in enumerate(resps):
        np.testing.assert_array_equal(np.asarray(r.result["p"]),
                                      np.asarray(sol.p[i]))
        assert r.result["converged"] == bool(sol.converged[i])
        assert r.result["iters"] == int(sol.iters[i])
        assert r.result["deviation"] == float(dev[i])


def test_calibrate_padded_bitwise_equals_direct(svc):
    """A γ-grid expansion padded to the rung == solve_batched directly."""
    from repro.core.duration import theoretical_duration
    from repro.mechanisms.batched import solve_batched

    grid, gamma_max, cost, n = 5, 2.0, 0.1, 4
    resp, = svc.serve([{"schema": SCHEMA, "kind": "calibrate",
                        "n_nodes": n, "cost": cost, "grid": grid,
                        "gamma_max": gamma_max, "ne_grid": 32,
                        "opt_grid": 32}])
    assert resp.ok

    gammas = np.linspace(0.0, gamma_max, grid)
    direct = solve_batched(
        jnp.asarray(gammas), jnp.full(grid, cost),
        theoretical_duration(n, d_inf=35.0, slope=8.0, horizon=500.0),
        ne_grid=32, opt_grid=32)
    poa = np.asarray(direct.poa)
    ok = np.isfinite(poa) & (poa <= 1.05)
    first = int(np.argmax(ok)) if ok.any() else int(np.argmin(poa))
    assert resp.result["achieved"] == bool(ok.any())
    assert resp.result["gamma_star"] == float(gammas[first])
    assert resp.result["poa"] == float(poa[first])
    assert resp.result["p_ne"] == float(direct.worst_ne[first])
    assert resp.result["opt_cost"] == float(direct.opt_cost[first])


def test_campaign_padded_bitwise_equals_direct(svc, task):
    """A single campaign row served in a padded bucket == run_campaigns."""
    from repro.federated.campaign import run_campaigns
    from repro.federated.simulation import FLConfig
    from repro.optim import sgd

    p = [0.5, 0.8]
    resps = svc.serve([
        {"schema": SCHEMA, "kind": "campaign", "p": p, "n_clients": 2,
         "rounds": 2, "seed": s} for s in (1, 2, 3)])
    assert [r.ok for r in resps] == [True] * 3
    assert {r.bucket for r in resps} == {"campaign/n2/b4"}

    fl = FLConfig(n_clients=2, local_steps=1, batch_per_client=8,
                  max_rounds=2, target_acc=0.73, consecutive=3)
    direct = run_campaigns(fl, *task.campaign_args(), sgd(0.15),
                           jnp.asarray([p] * 3, jnp.float64),
                           seeds=jnp.asarray([1, 2, 3], jnp.uint32))
    for i, r in enumerate(resps):
        assert r.result["energy_wh"] == float(direct.energy_wh[i])
        assert r.result["final_acc"] == float(direct.acc_history[i, -1])
        assert r.result["mean_aoi"] == float(direct.mean_aoi[i])
        assert r.result["participation_rate"] == \
            float(direct.participation_rate[i])
        assert r.result["rounds"] == int(direct.rounds[i])


def test_explicit_duration_table_matches_analytic(svc):
    """A dur.table equal to the analytic table serves identically."""
    from repro.core.duration import theoretical_duration

    tab = [float(x) for x in np.asarray(theoretical_duration(
        3, d_inf=35.0, slope=8.0, horizon=500.0).table())]
    base = {"schema": SCHEMA, "kind": "ne_solve",
            "costs": [0.05, 0.1, 0.2], "gammas": 1.0}
    r_analytic, = svc.serve([base])
    r_table, = svc.serve([{**base, "dur": {"table": tab}}])
    assert r_table.result == r_analytic.result


# ---------------------------------------------------------------------------
# compiled-program cache
# ---------------------------------------------------------------------------

def test_second_same_bucket_request_compiles_nothing(svc):
    req = {"schema": SCHEMA, "kind": "ne_solve",
           "costs": [0.2, 0.1, 0.3], "gammas": 0.8}
    svc.serve([req])  # warm (or already warm from the parity tests)
    before = svc.stats()
    r2, = svc.serve([dict(req, gammas=1.7)])  # same bucket, new data
    after = svc.stats()

    assert r2.ok
    assert after["cache"]["programs"] == before["cache"]["programs"]
    assert after["cache"]["misses"] == before["cache"]["misses"]
    assert after["cache"]["hits"] == before["cache"]["hits"] + 2  # solve+verify
    # per-bucket compile stats are flat; only the call counters move
    for label, stats in before["compile"].items():
        assert after["compile"][label]["compile_s"] == stats["compile_s"]
        assert after["compile"][label]["lower_s"] == stats["lower_s"]
    assert after["compile"]["ne/solve/n3/b1"]["calls"] == \
        before["compile"]["ne/solve/n3/b1"]["calls"] + 1


def test_different_rung_compiles_new_program(svc):
    ne = {"schema": SCHEMA, "kind": "ne_solve", "costs": [0.1, 0.2, 0.3]}
    svc.serve([ne])  # b1 rung
    before = svc.stats()["cache"]
    svc.serve([ne, dict(ne, gammas=1.0)])  # 2 rows -> b2 rung
    after = svc.stats()["cache"]
    assert after["programs"] == before["programs"] + 2  # solve + verify @ b2
    assert after["misses"] == before["misses"] + 2


def test_oversize_group_chunks_and_reuses_program(svc):
    """9 rows with max_batch=8 -> one b8 dispatch + one b1 dispatch."""
    reqs = [{"schema": SCHEMA, "kind": "ne_solve",
             "costs": [0.01 * (i + 1), 0.2], "gammas": 0.5}
            for i in range(9)]
    before = svc.stats()["dispatches"]
    resps = svc.serve(reqs)
    after = svc.stats()
    assert len(resps) == 9 and all(r.ok for r in resps)
    assert after["dispatches"] == before + 2
    assert {r.bucket for r in resps} == {"ne/n2/b8", "ne/n2/b1"}
    # chunk parity: row 8 (the b1 tail) matches a solo solve
    solo, = svc.serve([reqs[8]])
    assert solo.result == resps[8].result
