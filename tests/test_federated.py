"""FedAvg merge, participation, convergence tracker, end-to-end FL sim."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core  # noqa: F401
from repro.core.aoi import expected_aoi
from repro.core.controller import ParticipationController
from repro.federated.campaign import run_campaigns
from repro.federated.participation import mask_schedule, round_mask
from repro.federated.server import ConvergenceTracker, fedavg_merge
from repro.federated.simulation import (FLConfig, run_simulation,
                                        run_simulation_reference)
from repro.data.synthetic import SyntheticCifar, SyntheticLM
from repro.optim import sgd


def test_fedavg_merge_subset_mean():
    g = {"w": jnp.zeros((3, 2)), "b": jnp.zeros((2,))}
    c = {"w": jnp.stack([jnp.full((3, 2), i, jnp.float32) for i in range(4)]),
         "b": jnp.stack([jnp.full((2,), 10.0 * i) for i in range(4)])}
    mask = jnp.asarray([1, 0, 1, 0], bool)
    out = fedavg_merge(g, c, mask)
    np.testing.assert_allclose(np.asarray(out["w"]), 1.0)   # mean(0, 2)
    np.testing.assert_allclose(np.asarray(out["b"]), 10.0)  # mean(0, 20)


def test_fedavg_merge_empty_keeps_global():
    g = {"w": jnp.arange(6.0).reshape(3, 2)}
    c = {"w": jnp.ones((4, 3, 2))}
    out = fedavg_merge(g, c, jnp.zeros((4,), bool))
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]))


def test_fedavg_merge_weighted():
    g = {"w": jnp.zeros((1,))}
    c = {"w": jnp.asarray([[1.0], [3.0]])}
    out = fedavg_merge(g, c, jnp.asarray([1, 1], bool),
                       weights=jnp.asarray([3.0, 1.0]))
    np.testing.assert_allclose(np.asarray(out["w"]), [1.5])


def test_mask_schedule_deterministic_and_rate():
    p = jnp.full((20,), 0.3)
    m1 = mask_schedule(jax.random.PRNGKey(7), p, 500)
    m2 = mask_schedule(jax.random.PRNGKey(7), p, 500)
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
    assert abs(float(jnp.mean(m1)) - 0.3) < 0.02


def test_convergence_tracker_three_consecutive():
    tr = ConvergenceTracker.create(0.7, 3)
    accs = [0.5, 0.71, 0.72, 0.69, 0.75, 0.76, 0.77, 0.9]
    for i, a in enumerate(accs):
        tr = tr.update(jnp.asarray(a), jnp.asarray(i))
    # streak restarts at idx 3; rounds 4,5,6 hit -> converged at idx 6
    assert int(tr.converged_at) == 6


def test_controller_modes_order():
    """centralized p >= best-NE p at cost where tragedy bites."""
    ctrl_c = ParticipationController(n_nodes=50, gamma=0.0, cost=3.0,
                                     mode="centralized")
    ctrl_n = ParticipationController(n_nodes=50, gamma=0.0, cost=3.0,
                                     mode="ne_worst")
    assert ctrl_c.participation_probability() > \
        ctrl_n.participation_probability()


def _mlp_setup():
    data = SyntheticCifar(noise=2.5)

    def init_params(key):
        k1, k2 = jax.random.split(key)
        d = 32 * 32 * 3
        return {"w1": jax.random.normal(k1, (d, 32)) * d ** -0.5,
                "b1": jnp.zeros(32),
                "w2": jax.random.normal(k2, (32, 10)) * 32 ** -0.5,
                "b2": jnp.zeros(10)}

    def fwd(p, x):
        h = jax.nn.relu(x.reshape(x.shape[0], -1) @ p["w1"] + p["b1"])
        return h @ p["w2"] + p["b2"]

    def loss_fn(p, batch):
        lp = jax.nn.log_softmax(fwd(p, batch["images"]))
        return -jnp.mean(jnp.take_along_axis(lp, batch["labels"][:, None], 1))

    def eval_fn(p, batch):
        return jnp.mean(jnp.argmax(fwd(p, batch["images"]), -1)
                        == batch["labels"])

    def client_data(cid, rnd, n, steps):
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(0), cid), rnd)
        ks = jax.random.split(key, steps)
        return jax.vmap(lambda k: data.batch(k, n))(ks)

    return data, init_params, loss_fn, eval_fn, client_data


def test_fl_simulation_converges_and_meters_energy():
    data, init_params, loss_fn, eval_fn, client_data = _mlp_setup()
    fl = FLConfig(n_clients=8, local_steps=2, batch_per_client=16,
                  max_rounds=40, target_acc=0.73)
    res = run_simulation(fl, init_params, loss_fn, eval_fn, client_data,
                         data.val_set(256), sgd(0.05), p=0.6)
    assert res.converged
    assert res.rounds < 40
    # energy consistent with the ledger: rounds * [floor, full] band
    from repro.core.energy import EnergyParams
    ep = EnergyParams()
    lo = res.rounds * 8 * ep.e_idle_j / 3600.0
    hi = res.rounds * 8 * ep.e_participant_j / 3600.0
    assert lo <= res.energy_wh <= hi
    assert 0.3 < res.participation_rate < 0.9


def test_campaign_engine_matches_reference():
    """Scan-fused campaign == seed Python-loop oracle on the same scenario:
    convergence round, energy ledger, and accuracy trajectory."""
    data, init_params, loss_fn, eval_fn, client_data = _mlp_setup()
    fl = FLConfig(n_clients=8, local_steps=2, batch_per_client=16,
                  max_rounds=25, target_acc=0.73, seed=5)
    args = (fl, init_params, loss_fn, eval_fn, client_data,
            data.val_set(256), sgd(0.05))
    ref = run_simulation_reference(*args, p=0.5)
    eng = run_simulation(*args, p=0.5)
    assert eng.rounds == ref.rounds
    assert eng.converged == ref.converged
    # masks are drawn from the same RNG stream -> realized energy and
    # participation are bitwise-identical
    assert eng.energy_wh == ref.energy_wh
    assert eng.participation_rate == ref.participation_rate
    assert eng.ledger_summary["rounds"] == ref.ledger_summary["rounds"]
    np.testing.assert_allclose(eng.acc_history, ref.acc_history,
                               rtol=1e-9, atol=1e-12)


def test_campaign_batched_sweep_consistency():
    """One vmapped program over a p-grid: per-scenario accounting invariants
    + post-convergence rounds are no-ops."""
    data, init_params, loss_fn, eval_fn, client_data = _mlp_setup()
    fl = FLConfig(n_clients=8, local_steps=2, batch_per_client=16,
                  max_rounds=20, target_acc=0.73, seed=0)
    ps = jnp.asarray([0.25, 0.5, 0.85], jnp.float32)
    res = run_campaigns(fl, init_params, loss_fn, eval_fn, client_data,
                        data.val_set(256), sgd(0.05), ps)
    assert res.batch == 3
    rounds = np.asarray(res.rounds)
    assert np.all(rounds >= 1) and np.all(rounds <= fl.max_rounds)
    # the ledger stops exactly at convergence
    np.testing.assert_array_equal(np.asarray(res.ledger.rounds), rounds)
    # k_history agrees with the ledger's participation counts
    np.testing.assert_array_equal(
        np.asarray(res.k_history).sum(axis=1),
        np.asarray(res.ledger.participation_counts).sum(axis=1))
    # post-convergence accuracy entries repeat the last converged value
    for i in range(3):
        tail = np.asarray(res.acc_history[i])[rounds[i] - 1:]
        np.testing.assert_allclose(tail, tail[0])
        k_tail = np.asarray(res.k_history[i])[rounds[i]:]
        assert np.all(k_tail == 0)
    # realized participation tracks p within 4 binomial sigmas of the
    # realized draw count (few rounds -> wide band)
    p_np = np.asarray(ps, np.float64)
    draws = rounds * fl.n_clients
    sigma = np.sqrt(p_np * (1 - p_np) / draws)
    err = np.abs(np.asarray(res.participation_rate) - p_np)
    assert np.all(err <= 4 * sigma + 1e-9), (err, 4 * sigma)


def test_campaign_reports_realized_aoi():
    """In-loop AoI tracker: realized mean age tracks the renewal formula
    E[delta] = 1/p - 1/2 and decreases with participation."""
    data, init_params, loss_fn, eval_fn, client_data = _mlp_setup()
    # target > 1 never converges -> every round contributes AoI samples
    fl = FLConfig(n_clients=8, local_steps=1, batch_per_client=8,
                  max_rounds=60, target_acc=1.01, seed=2)
    ps = jnp.asarray([0.3, 0.8], jnp.float32)
    res = run_campaigns(fl, init_params, loss_fn, eval_fn, client_data,
                        data.val_set(64), sgd(0.05), ps)
    aoi = np.asarray(res.mean_aoi)
    want = np.asarray(expected_aoi(ps))
    assert aoi[0] > aoi[1]
    np.testing.assert_allclose(aoi, want, rtol=0.35)
    assert np.all(np.asarray(res.per_node_aoi) >= 0.5 - 1e-12)
    # the batched tracker's properties agree with the result fields
    np.testing.assert_array_equal(np.asarray(res.aoi.per_node_aoi),
                                  np.asarray(res.per_node_aoi))
    np.testing.assert_array_equal(np.asarray(res.aoi.mean_aoi), aoi)


def test_controller_solve_batched_matches_scalar():
    """The batched grid path returns the scalar participation_probability
    per scenario, without Python-level per-scenario solves."""
    costs = [1.0, 3.0, 6.0]
    ctrl = ParticipationController(n_nodes=50, gamma=0.0, cost=1.0)
    for mode in ("ne", "ne_worst", "centralized"):
        batched = np.asarray(ctrl.solve_batched(0.0, jnp.asarray(costs),
                                                mode=mode))
        for j, c in enumerate(costs):
            scalar = ParticipationController(
                n_nodes=50, gamma=0.0, cost=c,
                mode=mode).participation_probability()
            np.testing.assert_allclose(batched[j], scalar, atol=2e-3)
    fixed = np.asarray(ctrl.solve_batched(0.0, jnp.asarray(costs),
                                          mode="fixed"))
    np.testing.assert_allclose(fixed, ctrl.fixed_p)


def test_controller_solve_batched_mechanism_grid():
    """Mechanism mode: γ-grid calibration lifts every scenario's induced
    worst NE above the selfish one (grid-resolution agreement with the
    bisection-refined scalar path)."""
    costs = jnp.asarray([2.0, 5.0])
    ctrl = ParticipationController(n_nodes=50, gamma=0.0, cost=2.0)
    p_mech = np.asarray(ctrl.solve_batched(0.0, costs, mode="mechanism"))
    p_selfish = np.asarray(ctrl.solve_batched(0.0, costs, mode="ne_worst"))
    assert np.all(p_mech > p_selfish)
    scalar = ParticipationController(
        n_nodes=50, gamma=0.0, cost=5.0,
        mode="mechanism").participation_probability()
    np.testing.assert_allclose(p_mech[1], scalar, atol=0.05)


def test_fl_more_participation_not_slower():
    """p=0.9 should converge in <= rounds of p=0.15 (statistically robust
    at this noise level with fixed seeds)."""
    data, init_params, loss_fn, eval_fn, client_data = _mlp_setup()
    rounds = {}
    for p in (0.15, 0.9):
        fl = FLConfig(n_clients=8, local_steps=2, batch_per_client=16,
                      max_rounds=60, target_acc=0.73, seed=3)
        res = run_simulation(fl, init_params, loss_fn, eval_fn, client_data,
                             data.val_set(256), sgd(0.05), p=p)
        rounds[p] = res.rounds
    assert rounds[0.9] <= rounds[0.15]
