"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't die, without it
from hypothesis import given, settings, strategies as st  # noqa: E402

import repro.core  # noqa: F401
from repro.core.aoi import expected_aoi
from repro.core.energy import EnergyParams, expected_round_energy
from repro.core.poibin import poibin_pmf, poibin_pmf_recursive
from repro.federated.server import fedavg_merge
from repro.kernels.ref import fedavg_agg_ref

probs = st.lists(st.floats(0.0, 1.0, allow_nan=False), min_size=1,
                 max_size=24)


@settings(max_examples=30, deadline=None)
@given(probs)
def test_poibin_pmf_is_distribution(p):
    pmf = np.asarray(poibin_pmf(jnp.asarray(p)))
    assert pmf.shape == (len(p) + 1,)
    assert np.all(pmf >= -1e-12)
    assert abs(pmf.sum() - 1.0) < 1e-9


@settings(max_examples=30, deadline=None)
@given(probs)
def test_poibin_dft_equals_recursion(p):
    dft = np.asarray(poibin_pmf(jnp.asarray(p)))
    rec = np.asarray(poibin_pmf_recursive(jnp.asarray(p)))
    np.testing.assert_allclose(dft, rec, atol=1e-8)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 8), st.integers(1, 40), st.integers(0, 2 ** 31 - 1))
def test_fedavg_equals_subset_mean(n_clients, dim, seed):
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.normal(size=(dim,)), jnp.float32)}
    c = {"w": jnp.asarray(rng.normal(size=(n_clients, dim)), jnp.float32)}
    mask = jnp.asarray(rng.integers(0, 2, n_clients), bool)
    out = np.asarray(fedavg_merge(g, c, mask)["w"])
    sel = np.asarray(c["w"])[np.asarray(mask)]
    want = sel.mean(axis=0) if sel.size else np.asarray(g["w"])
    np.testing.assert_allclose(out, want, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 8), st.integers(1, 64), st.integers(0, 2 ** 31 - 1))
def test_fedavg_kernel_ref_matches_tree_merge(n_clients, dim, seed):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(dim,)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(n_clients, dim)), jnp.float32)
    mask = jnp.asarray(rng.integers(0, 2, n_clients), bool)
    a = np.asarray(fedavg_agg_ref(g, c, mask))
    b = np.asarray(fedavg_merge({"w": g}, {"w": c}, mask)["w"])
    np.testing.assert_allclose(a, b, atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(st.floats(1e-4, 1.0, allow_nan=False))
def test_aoi_monotone_decreasing(p):
    """More participation -> lower age, always >= 1/2."""
    a = float(expected_aoi(jnp.asarray(p)))
    a2 = float(expected_aoi(jnp.asarray(min(1.0, p * 1.5))))
    assert a >= a2 - 1e-9
    assert a >= 0.5 - 1e-9


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(0.0, 1.0), min_size=2, max_size=20))
def test_round_energy_monotone_in_p(p):
    ep = EnergyParams()
    base = float(expected_round_energy(jnp.asarray(p), ep))
    more = float(expected_round_energy(jnp.minimum(jnp.asarray(p) + 0.1, 1.0),
                                       ep))
    assert more >= base - 1e-9


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.floats(0.05, 0.95))
def test_expected_duration_bounds(seed, p):
    """E[D] lies within [min d, max d] of the duration table."""
    from repro.core.duration import paper_duration_model
    from repro.core.poibin import expected_duration
    dm = paper_duration_model()
    tab = dm.table()
    n = dm.n_nodes
    ed = float(expected_duration(jnp.full((n,), p), tab))
    assert float(jnp.min(tab)) - 1e-9 <= ed <= float(jnp.max(tab)) + 1e-9


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 2), st.sampled_from([32, 48, 64]),
       st.sampled_from([1, 2, 4]), st.sampled_from([16, 32]),
       st.integers(0, 2 ** 31 - 1))
def test_flash_attention_matches_sdpa(b, s, h, d, seed):
    """Property: the Pallas flash kernel equals reference attention for
    random (small) shapes, including non-tile-aligned sequence lengths."""
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.ref import flash_attention_ref
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    out = flash_attention(q, k, v, causal=True, block_q=16, block_k=16,
                          interpret=True)
    want = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=3e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 30), st.floats(0.5, 10.0), st.floats(0.0, 1.0),
       st.integers(0, 2 ** 31 - 1))
def test_heterogeneous_br_never_profitable_to_deviate(n, cost_hi, gamma,
                                                      seed):
    """Property: Gauss-Seidel BR dynamics land on profiles where no sampled
    unilateral deviation is profitable beyond solver tolerance."""
    from repro.core.asymmetric import HeterogeneousGame, best_response_dynamics
    from repro.core.duration import theoretical_duration
    rng = np.random.default_rng(seed)
    dur = theoretical_duration(n_nodes=n, d_inf=30.0, slope=6.0)
    costs = jnp.asarray(rng.uniform(0.1, cost_hi, n))
    game = HeterogeneousGame(costs=costs, gammas=jnp.full((n,), gamma),
                             dur=dur)
    p, conv, _ = best_response_dynamics(game, damping=0.6, max_iters=120)
    if not conv:
        return  # dynamics may cycle for gamma=0 bang-bang games; skip
    i = int(rng.integers(0, n))
    u_eq = float(game.utility(p, i))
    for q in np.linspace(1e-3, 1.0, 9):
        assert float(game.utility(p.at[i].set(float(q)), i)) <= u_eq + 1e-3
