"""Incentive-mechanism subsystem: batched solver parity + design results."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.controller import ParticipationController
from repro.core.duration import paper_duration_model
from repro.core.game import (P_MIN, centralized_optimum, solve_game,
                             solve_symmetric_ne)
from repro.core.utility import UtilityParams
from repro.mechanisms import (AoIRewardMechanism, StackelbergPlanner,
                              calibrate_gamma, calibrate_gamma_heterogeneous,
                              evaluate_mechanism, solve_batched,
                              solve_scenarios)
from helpers import assert_heterogeneous_ne, assert_symmetric_ne

N = 50
# (gamma, cost) settings spanning interior, multi-NE, and corner-collapse
# regimes of the paper's calibration.
CASES = [(0.0, 0.0), (0.0, 1.5), (0.6, 2.0), (0.0, 60.0), (0.6, 60.0),
         (1.2, 8.0)]


@pytest.fixture(scope="module")
def dur():
    return paper_duration_model()


@pytest.fixture(scope="module")
def batch(dur):
    return solve_batched(jnp.asarray([g for g, _ in CASES]),
                         jnp.asarray([c for _, c in CASES]), dur)


# ---- batched solver vs the scalar oracles ---------------------------------

@pytest.mark.parametrize("i", range(len(CASES)))
def test_batched_ne_matches_scalar(dur, batch, i):
    gamma, cost = CASES[i]
    up = UtilityParams(gamma=gamma, cost=cost, n_nodes=N)
    scalar = solve_symmetric_ne(up, dur, grid_size=400)
    batched = batch.equilibria_list(i)
    assert len(batched) == len(scalar), (scalar, batched)
    np.testing.assert_allclose(batched, scalar, atol=1e-3)


@pytest.mark.parametrize("i", range(len(CASES)))
def test_batched_optimum_matches_scalar(dur, batch, i):
    gamma, cost = CASES[i]
    up = UtilityParams(gamma=gamma, cost=cost, n_nodes=N)
    opt_p, opt_cost = centralized_optimum(up, dur)
    assert abs(float(batch.opt_p[i]) - opt_p) < 1e-3
    # golden refinement may only improve on the scalar grid argmin
    assert float(batch.opt_cost[i]) <= opt_cost + 1e-9
    np.testing.assert_allclose(float(batch.opt_cost[i]), opt_cost, rtol=1e-4)


def test_batched_corner_ne_semantics(dur, batch):
    """The c=60, γ=0 collapse keeps the P_MIN corner NE (Tragedy basin)."""
    i = CASES.index((0.0, 60.0))
    eqs = batch.equilibria_list(i)
    assert eqs and abs(eqs[0] - P_MIN) < 1e-12
    assert float(batch.poa[i]) > 2.0  # collapse is catastrophic


def test_batched_shapes_and_padding(batch):
    b = len(CASES)
    assert batch.poa.shape == (b,)
    assert batch.equilibria.shape == batch.ne_costs.shape
    assert batch.ne_mask.shape == batch.equilibria.shape
    # padded slots are NaN, valid slots finite and ascending
    eq = np.asarray(batch.equilibria)
    mask = np.asarray(batch.ne_mask)
    assert np.all(np.isnan(eq[~mask]))
    for i in range(b):
        row = eq[i][mask[i]]
        assert np.all(np.isfinite(row))
        assert np.all(np.diff(row) > 0)


def test_batched_worst_best_consistent(batch):
    costs = np.asarray(batch.ne_costs)
    mask = np.asarray(batch.ne_mask)
    for i in range(len(CASES)):
        valid = costs[i][mask[i]]
        assert float(batch.worst_ne_cost[i]) == pytest.approx(valid.max())
        assert float(batch.best_ne_cost[i]) == pytest.approx(valid.min())
        assert float(batch.worst_ne_cost[i]) >= float(batch.opt_cost[i]) - 1e-9


def test_solve_game_delegation_keeps_api(dur):
    """solve_game (now batched-backed) preserves the GameSolution contract."""
    sol = solve_game(UtilityParams(gamma=0.0, cost=1.5, n_nodes=N), dur)
    assert sol.equilibria == sorted(sol.equilibria)
    assert len(sol.ne_costs) == len(sol.equilibria)
    assert sol.poa >= 1.0
    with pytest.raises(ValueError):
        solve_game(UtilityParams(gamma=0.0, cost=1.5, n_nodes=N + 1), dur)


def test_solve_scenarios_groups_by_n(dur):
    from repro.core.duration import theoretical_duration
    d30 = theoretical_duration(30)
    scen = [UtilityParams(gamma=0.0, cost=2.0, n_nodes=50),
            UtilityParams(gamma=0.3, cost=1.0, n_nodes=30),
            UtilityParams(gamma=0.0, cost=4.0, n_nodes=30)]
    sols = solve_scenarios(scen, {50: dur, 30: d30})
    assert [s.batch for s in sols] == [2, 1]  # ascending N: 30-group, 50-group
    assert np.all(np.isfinite(np.asarray(sols[0].opt_cost)))


# ---- AoI-reward calibration ------------------------------------------------

def test_calibration_closes_the_poa_gap(dur):
    """γ* shrinks PoA below 1.05 on a scenario with uncalibrated PoA ≥ 1.28."""
    base = UtilityParams(gamma=0.0, cost=5.0, n_nodes=N)
    uncal = solve_game(base, dur)
    assert uncal.poa >= 1.28, uncal.poa  # the paper's headline gap
    cal = calibrate_gamma(base, dur, target_poa=1.04)
    assert cal.achieved
    rep = evaluate_mechanism(cal.mechanism, base, dur)
    assert rep.poa < 1.05, rep.poa
    assert cal.gamma_star > 0.0
    assert rep.individually_rational
    assert rep.planner_budget >= 0.0
    # the worst induced NE is a certified equilibrium of the induced game
    assert_symmetric_ne(rep.ne_p, rep.induced_params, dur)


def test_calibration_reports_unreachable_targets(dur):
    base = UtilityParams(gamma=0.0, cost=5.0, n_nodes=N)
    cal = calibrate_gamma(base, dur, target_poa=1.0 + 1e-9, gamma_max=0.05,
                          coarse=8)
    assert not cal.achieved
    # best-effort fallback: the scan's best γ, never a blindly-maximal one
    best = int(np.argmin(np.asarray(cal.grid_poas)))
    assert cal.gamma_star == pytest.approx(float(cal.grid_gammas[best]))
    assert cal.poa == pytest.approx(float(cal.grid_poas[best]))
    # and it can never be worse than applying no mechanism at all (γ=0 is
    # on the grid)
    assert cal.poa <= float(cal.grid_poas[0]) + 1e-12


def test_aoi_transfer_nonnegative(dur):
    mech = AoIRewardMechanism(gamma_star=0.7)
    base = UtilityParams(gamma=0.0, cost=2.0, n_nodes=N)
    for p in [P_MIN, 0.1, 0.5, 1.0]:
        assert mech.transfer(p, base) >= 0.0
    assert mech.transfer(P_MIN, base) == pytest.approx(0.0)
    assert mech.induced_params(base).gamma == pytest.approx(0.7)


# ---- heterogeneous-population calibration ----------------------------------

HET_N = 12


@pytest.fixture(scope="module")
def het_dur():
    from repro.core.duration import theoretical_duration
    return theoretical_duration(n_nodes=HET_N, d_inf=35.0, slope=8.0)


@pytest.fixture(scope="module")
def het_costs():
    return jnp.asarray(np.linspace(0.5, 8.0, HET_N))


def test_heterogeneous_calibration_hits_target(het_dur, het_costs):
    cal = calibrate_gamma_heterogeneous(het_costs, het_dur, target_poa=1.05,
                                        damping=0.6, max_iters=300)
    assert cal.achieved
    assert cal.poa <= 1.05 + 1e-9
    assert cal.gamma_star > 0.0  # the selfish fleet misses the target...
    assert float(cal.grid_poas[0]) > 1.05  # ...so γ = 0 alone is not enough
    assert cal.deviation <= 1e-4  # the calibrated NE is certified
    # γ* is minimal on the scan: every smaller grid γ misses the target
    smaller = np.asarray(cal.grid_gammas) < cal.gamma_star
    assert np.all(np.asarray(cal.grid_poas)[smaller] > 1.05)
    # and the mechanism's induced NE really is an equilibrium of the
    # γ-shifted heterogeneous game
    gammas = jnp.full((HET_N,), cal.gamma_star)
    rep = cal.grid_report
    assert rep.batch == len(np.asarray(cal.grid_gammas))
    from repro.core.asymmetric_batched import solve_heterogeneous
    sol = solve_heterogeneous(het_costs, gammas, het_dur, damping=0.6,
                              max_iters=300)
    p, conv, _ = sol.single()
    assert conv
    assert_heterogeneous_ne(het_costs, gammas, het_dur, p)


def test_heterogeneous_calibration_unreachable_target(het_dur, het_costs):
    cal = calibrate_gamma_heterogeneous(het_costs, het_dur,
                                        target_poa=1.0 + 1e-9, gamma_max=1.0,
                                        coarse=8, damping=0.6, max_iters=300)
    assert not cal.achieved
    # best-effort fallback: the scan's best γ, never a blindly-maximal one
    poas = np.asarray(cal.grid_poas)
    best = int(np.argmin(poas))
    assert cal.gamma_star == pytest.approx(float(cal.grid_gammas[best]))
    assert cal.poa == pytest.approx(float(poas[best]))
    # never worse than applying no mechanism at all (γ = 0 is on the grid)
    assert cal.poa <= float(poas[0]) + 1e-12


# ---- Stackelberg pricing ---------------------------------------------------

def test_stackelberg_is_ir_and_budget_reported(dur):
    base = UtilityParams(gamma=0.0, cost=8.0, n_nodes=N)
    sol = StackelbergPlanner(budget_weight=0.1).solve(base, dur)
    assert sol.report.individually_rational
    assert sol.planner_spend_per_round >= 0.0
    assert sol.report.planner_budget == pytest.approx(
        sol.planner_spend_per_round)
    # the subsidy must not make things worse than the r=0 status quo
    assert sol.report.ne_cost <= sol.baseline_cost + 1e-9
    assert sol.energy_saved_wh > 0.0


def test_stackelberg_target_poa_picks_cheapest_rate(dur):
    base = UtilityParams(gamma=0.0, cost=8.0, n_nodes=N)
    tight = StackelbergPlanner(target_poa=1.05).solve(base, dur)
    loose = StackelbergPlanner(target_poa=1.25).solve(base, dur)
    assert tight.report.poa <= 1.05 + 1e-6
    assert loose.rate <= tight.rate + 1e-9


# ---- controller wiring -----------------------------------------------------

def test_controller_mechanism_mode(dur):
    c = 5.0
    selfish = ParticipationController(n_nodes=N, gamma=0.0, cost=c,
                                      mode="ne_worst")
    mech = ParticipationController(n_nodes=N, gamma=0.0, cost=c,
                                   mode="mechanism")
    p_selfish = selfish.participation_probability()
    p_mech = mech.participation_probability()
    assert p_mech > p_selfish  # incentive lifts the worst equilibrium
    d = mech.diagnostics()
    assert d["mechanism"] == "aoi_reward"
    assert d["mechanism_poa"] <= mech.target_poa + 1e-9
    assert d["individually_rational"]
    assert d["planner_budget"] >= 0.0


def test_controller_explicit_mechanism(dur):
    ctrl = ParticipationController(
        n_nodes=N, gamma=0.0, cost=2.0, mode="mechanism",
        mechanism=AoIRewardMechanism(gamma_star=0.6))
    p = ctrl.participation_probability()
    assert 0.4 < p <= 1.0  # paper Fig. 4: γ=0.6 keeps participation high


def _report_without_induced_ne(n=N):
    """A MechanismReport for the 'no induced NE' branch (ne_p = NaN) —
    what evaluate_mechanism returns when the induced game has no
    equilibrium."""
    from repro.mechanisms.base import MechanismReport
    base = UtilityParams(gamma=0.0, cost=5.0, n_nodes=n)
    return MechanismReport(
        mechanism="aoi_reward", base_params=base, induced_params=base,
        equilibria=[], ne_costs=[], ne_p=float("nan"),
        ne_cost=float("nan"), opt_p=0.6, opt_cost=40.0, poa=float("inf"),
        transfer_per_node=0.0, planner_budget=0.0, ir_slack=float("-inf"),
        individually_rational=False)


def test_controller_mechanism_nan_no_induced_ne_path():
    """ne_p = NaN must not propagate: the controller falls back to p = 0
    (nobody participates) and diagnostics flag the missed target."""
    ctrl = ParticipationController(n_nodes=N, gamma=0.0, cost=5.0,
                                   mode="mechanism",
                                   _mech_report=_report_without_induced_ne())
    p = ctrl.participation_probability()
    assert p == 0.0 and not np.isnan(p)
    d = ctrl.diagnostics()
    assert d["mechanism_target_met"] is False
    assert d["p"] == 0.0
    assert np.isinf(d["mechanism_poa"])
    assert not d["individually_rational"]


def test_controller_mechanism_target_met_reporting(dur):
    """The happy path must report mechanism_target_met = True — and the
    flag must track poa <= target_poa exactly."""
    ctrl = ParticipationController(n_nodes=N, gamma=0.0, cost=5.0,
                                   mode="mechanism", target_poa=1.05)
    d = ctrl.diagnostics()
    assert d["mechanism_target_met"] is (d["mechanism_poa"]
                                         <= ctrl.target_poa + 1e-9)
    assert d["mechanism_target_met"] is True


def test_controller_solve_batched_honours_explicit_mechanism(dur):
    """solve_batched(mode="mechanism") must use a supplied mechanism's
    transfer (scalar-path parity), not re-calibrate its own γ."""
    ctrl = ParticipationController(
        n_nodes=N, gamma=0.0, cost=2.0, mode="mechanism",
        mechanism=AoIRewardMechanism(gamma_star=0.6))
    p_scalar = ctrl.participation_probability()
    p_batched = float(ctrl.solve_batched(0.0, 2.0)[0])
    assert p_batched == pytest.approx(p_scalar, abs=2e-3)
